"""Mesh-sharded paged serving (DESIGN.md §11).

Two tiers:

* host-only unit tests — per-shard page pool semantics, `mesh=`
  admission validation (FakeMesh: every case raises before any device
  work), fused-grid page bucketing, table-row compaction and step-meta
  width — all run on the normal 1-device session;
* subprocess integration tests (``@pytest.mark.slow``) — forced
  8-device host platform via ``XLA_FLAGS`` in a child process (the flag
  must never leak into the main session), asserting sharded greedy
  tokens are bit-identical to the single-device engine, per-shard page
  ranges, per-shard free-list accounting and the steady-state
  zero-``device_get`` invariant.
"""
import dataclasses
import logging
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import configs
from repro.kernels import paged_decode
from repro.models import model as M
from repro.models import modules as mm

REPO = Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def apack_cfg(arch="qwen3-1.7b"):
    return dataclasses.replace(configs.get_smoke_config(arch),
                               kv_cache_dtype="apack-int8")


# ------------------------------------------------- per-shard page pool
class TestShardedPool:
    def _pool(self, num_pages=16, n_shards=4):
        return mm.KVPagePool(num_pages, page_size=4, kv_heads=2,
                             head_dim=8, n_shards=n_shards)

    def test_alloc_stays_in_shard_range(self):
        pool = self._pool()
        for shard in range(4):
            lo, hi = shard * 4, (shard + 1) * 4
            for _ in range(4):
                pid = pool.alloc(shard)
                assert pid is not None and lo <= pid < hi
                assert pool.shard_of(pid) == shard

    def test_exhausted_shard_returns_none_not_steal(self):
        pool = self._pool()
        for _ in range(4):
            assert pool.alloc(1) is not None
        # shard 1 dry: its alloc fails while every other shard still serves
        assert pool.alloc(1) is None
        assert pool.free_count_shard(1) == 0
        for shard in (0, 2, 3):
            assert pool.alloc(shard) is not None

    def test_free_routes_back_to_owning_shard(self):
        pool = self._pool()
        pids = [pool.alloc(2) for _ in range(4)]
        assert pool.free_count_shard(2) == 0
        for pid in pids:
            pool.free(pid)
        assert pool.free_count_shard(2) == 4
        # and the freed pages come back out of shard 2, nowhere else
        assert pool.shard_of(pool.alloc(2)) == 2

    def test_free_count_is_sum_of_shards(self):
        pool = self._pool()
        pool.alloc(0), pool.alloc(3)
        assert pool.free_count == sum(pool.free_count_shard(s)
                                      for s in range(4))
        assert pool.free_count == 14

    def test_indivisible_pool_rejected(self):
        with pytest.raises(ValueError, match="split evenly"):
            self._pool(num_pages=14, n_shards=4)

    def test_single_shard_is_legacy_pool(self):
        # n_shards=1 must be the old global free list bit-for-bit:
        # lowest page id first
        pool = self._pool(n_shards=1)
        assert [pool.alloc() for _ in range(4)] == [0, 1, 2, 3]


# ------------------------------------------------- mesh= admission gate
class FakeMesh:
    """Axis sizes only — what the constructor validation consumes.
    Every test below must raise *before* the engine touches the mesh as
    a real device mesh."""
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestMeshValidation:
    def _engine(self, cfg, mesh, **kw):
        from repro.serve import ServeEngine
        params = M.init_params(cfg, __import__("jax").random.PRNGKey(0))
        return ServeEngine(cfg, params, max_batch=8, max_len=32,
                           mesh=mesh, **kw)

    def test_requires_fused_paged_kv(self):
        cfg = dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                                  kv_cache_dtype="bfloat16")
        with pytest.raises(ValueError, match="fused paged apack-int8"):
            self._engine(cfg, FakeMesh(data=2, model=1))

    def test_requires_fused_not_materialize(self):
        with pytest.raises(ValueError, match="fused paged apack-int8"):
            self._engine(apack_cfg(), FakeMesh(data=2, model=1),
                         kv_fused=False)

    def test_requires_sync_scheduler(self):
        with pytest.raises(ValueError, match="scheduler='sync'"):
            self._engine(apack_cfg(), FakeMesh(data=2, model=1),
                         scheduler="async")

    def test_requires_data_axis(self):
        with pytest.raises(ValueError, match="'data' axis"):
            self._engine(apack_cfg(), FakeMesh(model=2))

    def test_max_batch_must_divide_over_data(self):
        with pytest.raises(ValueError, match="max_batch"):
            self._engine(apack_cfg(), FakeMesh(data=3, model=1))

    def test_kv_heads_must_divide_over_model(self):
        # qwen3 smoke has 2 kv heads; a 3-way model axis cannot split them
        with pytest.raises(ValueError, match="num_kv_heads"):
            self._engine(apack_cfg(), FakeMesh(data=1, model=3))


# ------------------------------------------------- fused-grid bucketing
class TestPageBucket:
    def test_powers_of_two(self):
        assert paged_decode.page_bucket(1) == 1
        assert paged_decode.page_bucket(3) == 4
        assert paged_decode.page_bucket(9) == 16
        assert paged_decode.page_bucket(129) == 256

    def test_beyond_table_grows_power_of_two(self):
        assert paged_decode.page_bucket(1025) == 2048
        assert paged_decode.page_bucket(5000) == 8192

    def test_recompile_storm_warns(self, monkeypatch, caplog):
        monkeypatch.setattr(paged_decode, "_seen_page_buckets", set())
        monkeypatch.setattr(paged_decode, "PAGE_BUCKET_WARN_THRESHOLD", 3)
        with caplog.at_level(logging.WARNING,
                             logger="repro.kernels.paged_decode"):
            for n in (1, 2, 4):
                paged_decode.page_bucket(n)
            assert not caplog.records          # at threshold: quiet
            paged_decode.page_bucket(8)        # 4th distinct size: warn
            assert len(caplog.records) == 1
            assert "recompile storm" in caplog.records[0].message
            paged_decode.page_bucket(8)        # repeat size: no new warn
            assert len(caplog.records) == 1


class TestMetaPagesBucketing:
    def _kv(self, tokens_per_rid):
        cfg = apack_cfg()
        kv = M.PagedKVCache(
            cfg, num_pages=4 * M.PagedKVCache.pages_for_config(cfg, 64, 4),
            page_size=4, calib_pages=2)
        rng = np.random.default_rng(3)
        h, dh, n = kv.pool.kv_heads, kv.pool.head_dim, kv.n_layers
        for rid, toks in tokens_per_rid.items():
            kv.add_request(rid)
            for _ in range(toks):
                kv.append_token(
                    rid,
                    rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
                    rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
                    rng.uniform(0.01, 0.02, (n, h)).astype(np.float32),
                    rng.uniform(0.01, 0.02, (n, h)).astype(np.float32))
        return kv

    @staticmethod
    def _pid_width(meta):
        for md in list(meta["prefix"]) + list(meta["blocks"]):
            if md:
                return np.asarray(md["pid"]).shape[-1]
        raise AssertionError("no attention metadata")

    def test_static_worst_case_without_slots(self):
        kv = self._kv({})
        assert kv.meta_pages(64, None) == kv.pages_per_seq(64)

    def test_short_requests_get_small_bucket(self):
        kv = self._kv({0: 5, 1: 3})          # 2 and 1 occupied pages
        pmax = kv.pages_per_seq(64)
        assert kv.meta_pages(64, [0, 1, None]) == 2 < pmax
        meta = kv.step_meta([0, 1, None], 64)
        assert self._pid_width(meta) == 2

    def test_bucket_caps_at_worst_case(self):
        kv = self._kv({0: 5})
        assert kv.meta_pages(8, [0]) <= kv.pages_per_seq(8)


# ------------------------------------------------- table-row compaction
class TestTableRowCompaction:
    def _kv(self):
        cfg = apack_cfg()
        kv = M.PagedKVCache(
            cfg, num_pages=4 * M.PagedKVCache.pages_for_config(cfg, 64, 4),
            page_size=4, calib_pages=2,
            refresh_every_pages=4, refresh_min_pages=1)
        rng = np.random.default_rng(7)
        h, dh, n = kv.pool.kv_heads, kv.pool.head_dim, kv.n_layers

        def extend(rid, toks):
            for _ in range(toks):
                kv.append_token(
                    rid,
                    rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
                    rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
                    rng.uniform(0.01, 0.02, (n, h)).astype(np.float32),
                    rng.uniform(0.01, 0.02, (n, h)).astype(np.float32))
        for rid, toks in ((0, 19), (1, 10)):
            kv.add_request(rid)
            extend(rid, toks)
        return kv, extend

    def test_dead_generation_rows_are_reclaimed(self):
        kv, extend = self._kv()
        assert kv.maybe_refresh()
        assert kv.repack_pending(force=True) > 0
        assert set(kv.gen_rows) == {0, 1}
        before = kv.materialize([0, 1], 64)
        rows_before = kv.n_table_rows
        # second refresh re-packs every gen-1 page under gen 2 -> gen 1
        # owns no PACKED page and its stacked-table row is reclaimed
        extend(0, 17), extend(1, 17)
        assert kv.maybe_refresh()
        assert kv.repack_pending(force=True) > 0
        assert 1 not in kv.gen_rows, kv.gen_rows
        assert set(kv.gen_rows) == {0, kv.generation}
        # the freed row slot was reused, not appended after
        assert kv.n_table_rows == rows_before
        gens = {int(kv.page_gen[p]) for s in kv._packed for p in s}
        assert gens == {kv.generation}
        # decode of the pre-compaction tokens is unchanged over pages
        # already sealed at the 'before' shot (time axis 2; both requests
        # had sealed tokens 0..7 — later tokens sat in a HOT page whose
        # sealing legitimately requantizes per-token to per-page scales)
        after = kv.materialize([0, 1], 64)
        for a, b in zip(before["blocks"], after["blocks"]):
            if "k" not in a:
                continue
            for f in ("k", "v", "k_scale", "v_scale"):
                np.testing.assert_array_equal(
                    np.asarray(a[f])[:, :, :8], np.asarray(b[f])[:, :, :8])


# ------------------------------------------ multi-device (subprocess)
_SERVE_COMMON = r"""
import dataclasses
import numpy as np
import jax
from repro import configs
from repro.models import model as M
from repro.serve import ServeEngine, Request

def apack_cfg(arch):
    return dataclasses.replace(configs.get_smoke_config(arch),
                               kv_cache_dtype="apack-int8")

def make(cfg, params, mesh, **kw):
    eng = ServeEngine(cfg, params, max_batch=8, max_len=32, mesh=mesh, **kw)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 9).astype(np.int32),
                    max_new_tokens=8)
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    return eng, reqs

def drain(eng, reqs):
    eng.run_until_drained()
    assert all(r.done and r.error is None for r in reqs), \
        [(r.rid, r.error) for r in reqs]
    return [list(r.tokens) for r in reqs]
"""


@pytest.mark.slow
def test_mesh_8x1_tokens_bit_identical_and_invariants():
    """8-way data-parallel serving: greedy tokens bit-identical to the
    single-device engine; mid-serve every request's pages live inside
    its slot-shard's contiguous page range; a steady-state step makes
    zero ``jax.device_get`` calls and moves zero accounted d2h bytes;
    drained free lists restore per shard."""
    print(run_py(_SERVE_COMMON + r"""
cfg = apack_cfg("qwen3-1.7b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

eng1, reqs1 = make(cfg, params, None)
single = drain(eng1, reqs1)

mesh = jax.make_mesh((8, 1), ("data", "model"))
eng, reqs = make(cfg, params, mesh)
for _ in range(3):
    eng.step()
# per-shard page-range invariant: slot s's request allocates only from
# shard (s // slots_per_shard)'s contiguous range
pps = eng.kv.pool.pages_per_shard
for slot, r in enumerate(eng.active):
    if r is None:
        continue
    shard = slot // (eng.max_batch // 8)
    for pids in eng.kv.page_tables[r.rid]:
        assert all(p // pps == shard for p in pids), (slot, shard, pids)
st = eng.kv_stats()
assert len(st["kv_shard_free"]) == 8 and len(st["kv_shard_reserved"]) == 8
assert sum(st["kv_shard_reserved"]) == eng._reserved_total
# steady state (positions 12 -> mid-page everywhere at page_size=16):
# zero device_get, zero accounted d2h traffic
d2h0 = st["transfers"]["d2h_bytes"], st["transfers"]["d2h_calls"]
calls = []
orig = jax.device_get
jax.device_get = lambda *a, **k: (calls.append(a), orig(*a, **k))[1]
try:
    eng.step()
finally:
    jax.device_get = orig
assert not calls, f"device_get on the steady-state sharded step: {calls}"
tr = eng.kv_stats()["transfers"]
assert (tr["d2h_bytes"], tr["d2h_calls"]) == d2h0
sharded = drain(eng, reqs)
assert sharded == single, (sharded, single)
# drained: every page back on its own free list
assert [eng.kv.pool.free_count_shard(s) for s in range(8)] == [pps] * 8
print("MESH 8x1 TOKENS IDENTICAL OK")
"""))


@pytest.mark.slow
def test_mesh_4x2_tensor_parallel_parity():
    """data×model = 4×2: kv-heads split over the model axis inside the
    fused gather-decode kernel, tokens still bit-identical — on the
    uniform-attention arch and the hybrid global/local/recurrent one."""
    print(run_py(_SERVE_COMMON + r"""
for arch in ("qwen3-1.7b", "hetero-serve-smoke"):
    cfg = apack_cfg(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng1, reqs1 = make(cfg, params, None)
    single = drain(eng1, reqs1)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    eng, reqs = make(cfg, params, mesh)
    assert eng._n_model == 2
    sharded = drain(eng, reqs)
    assert sharded == single, (arch, sharded, single)
    print("MESH 4x2 TP OK", arch)
"""))


@pytest.mark.slow
def test_mesh_preempt_spill_resume_parity():
    """Preempt-with-spill and resume on the sharded engine: same slot
    preempted at the same step on both engines, final tokens still
    bit-identical (spilled requests may re-adopt a different shard —
    byte-identical continuation is shard-independent)."""
    print(run_py(_SERVE_COMMON + r"""
cfg = apack_cfg("hetero-serve-smoke")
params = M.init_params(cfg, jax.random.PRNGKey(0))

def serve(mesh):
    eng, reqs = make(cfg, params, mesh)
    for _ in range(3):
        eng.step()
    eng.preempt(2, spill=True)
    eng.preempt(5, spill=False)
    return drain(eng, reqs)

single = serve(None)
sharded = serve(jax.make_mesh((8, 1), ("data", "model")))
assert sharded == single, (sharded, single)
print("MESH PREEMPT/SPILL/RESUME OK")
"""))

"""Heterogeneous-stack paged KV tests: rolling-window page eviction,
recurrent-state snapshot compression (engine checkpoint/preemption),
per-kind admission reservation, multi-table gather-decode, pool-invariant
hardening under ``python -O``, and teacher-forced decode parity on a
global + local + recurrent cycle."""
import dataclasses
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import configs
from repro.core import tables
from repro.kernels import ref as _ref
from repro.kernels.paged_decode import gather_decode
from repro.models import model as M
from repro.models import modules as m
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)
# repro is a namespace package (no top-level __init__): use __path__
SRC = Path(list(repro.__path__)[0]).resolve().parent


def hetero_cfg(**kw):
    return dataclasses.replace(configs.get_hetero_smoke_config(),
                               kv_cache_dtype="apack-int8", **kw)


def _random_token(rng, kv, lo=0.01, hi=0.02):
    h, dh = kv.pool.kv_heads, kv.pool.head_dim
    n = kv.n_layers
    return (rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.uniform(lo, hi, (n, h)).astype(np.float32),
            rng.uniform(lo, hi, (n, h)).astype(np.float32))


# ------------------------------------------------------ rolling eviction
class TestRollingEviction:
    def test_eviction_frees_exactly_the_rolled_out_page(self):
        """Alloc/free trace of a rolling layer: the oldest page frees the
        step its last token leaves the window, newer pages are untouched,
        and the live set never exceeds ``window_pages``."""
        cfg = dataclasses.replace(
            configs.get_smoke_config("qwen3-1.7b"), num_layers=1,
            block_pattern=("local",), window_size=8,
            kv_cache_dtype="apack-int8")
        kv = M.PagedKVCache(cfg, num_pages=16, page_size=4, calib_pages=1)
        kv.add_request(0)
        rng = np.random.default_rng(0)
        trace = []                       # (seq_len, base, live pids, free)
        for t in range(24):
            kv.append_token(0, *_random_token(rng, kv))
            trace.append((kv.seq_len[0], kv.page_base[0][0],
                          list(kv.page_tables[0][0]), kv.pool.free_count))
        first_pid = trace[0][2][0]
        for seq_len, base, pids, _ in trace:
            # page p (tokens [4p, 4p+4)) dies once 4p+3 <= seq_len - 8:
            # the page table's base must track that frontier exactly
            assert base == max(0, (seq_len - 8 + 1) // 4), (seq_len, base)
            assert len(pids) <= kv.window_pages
            # the oldest page is evicted precisely at seq_len = 11 (it may
            # legitimately reappear later, recycled off the free list)
            if seq_len <= 10:
                assert first_pid in pids, (seq_len, pids)
            elif seq_len <= 12:
                assert first_pid not in pids, (seq_len, pids)
        # only eviction frees pages here, and the free count visibly
        # increases while the sequence grows (the acceptance observable)
        rises = [(a, b) for (_, _, _, a), (_, _, _, b)
                 in zip(trace, trace[1:]) if b > a]
        assert rises, "free count never increased while growing"
        assert kv.pool.evict_count == trace[-1][1]      # evictions == base
        kv.release(0)
        assert kv.pool.free_count == kv.pool.num_pages

    def test_evicted_tokens_never_materialized(self):
        """Materialize after eviction rebuilds only the ring; the rolled
        out tokens' pages are gone from the table entirely."""
        cfg = dataclasses.replace(
            configs.get_smoke_config("qwen3-1.7b"), num_layers=1,
            block_pattern=("local",), window_size=8,
            kv_cache_dtype="apack-int8")
        kv = M.PagedKVCache(cfg, num_pages=16, page_size=4, calib_pages=1)
        kv.add_request(0)
        rng = np.random.default_rng(1)
        toks = [_random_token(rng, kv) for _ in range(20)]
        for t in toks:
            kv.append_token(0, *t)
        cache = kv.materialize([0], 32)
        ring = min(8, 32)
        got_k = np.asarray(cache["blocks"][0]["k"])[0, 0]      # [ring, H, dh]
        assert got_k.shape[0] == ring
        # slot a % ring holds token a for a in [20 - ring, 20)
        pool = kv.pool
        live_pids = kv.page_tables[0][0]
        assert all(int(pool.state[p]) != m.PAGE_FREE for p in live_pids)
        # nothing outside the live window was read
        assert kv.traffic["kv_raw_bytes_local"] > 0
        assert kv.traffic["kv_raw_bytes_global"] == 0


# ------------------------------------------------- per-kind reservation
class TestPerKindAdmission:
    def test_pages_needed_per_layer_kind(self):
        """global layers reserve the full sequence, rolling layers cap at
        ceil(window/page)+1, recurrent-kind layers reserve nothing."""
        cfg = hetero_cfg()           # prefix recurrent + (global,local,rec)
        kv = M.PagedKVCache(cfg, num_pages=4, page_size=4)
        assert kv.window_pages == 8 // 4 + 1
        assert kv.pages_needed(32) == 32 // 4 + kv.window_pages   # 8 + 3
        assert kv.pages_needed(4) == 1 + 1                        # both tiny
        assert M.PagedKVCache.pages_for_config(cfg, 32, 4) == 11
        # all-recurrent stack needs no pages at all
        xc = dataclasses.replace(configs.get_smoke_config("xlstm-125m"),
                                 kv_cache_dtype="apack-int8")
        assert M.PagedKVCache.pages_for_config(xc, 128, 4) == 0

    def test_engine_reserves_per_kind_and_recovers(self):
        """Pool sized for exactly one heterogeneous request: admission
        blocks the second despite free slots, and eviction churn does not
        corrupt the reservation accounting."""
        cfg = hetero_cfg()
        params = M.init_params(configs.get_hetero_smoke_config(), KEY)
        # each request stores min(max_len, prompt 8 + new 4) = 12 tokens
        per_req = M.PagedKVCache.pages_for_config(cfg, 12, 4)
        eng = ServeEngine(cfg, params, max_batch=4, max_len=16,
                          kv_page_size=4, kv_calib_pages=2,
                          kv_pages=per_req)
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng._retire()
        eng._admit()
        assert sum(r is not None for r in eng.active) == 1
        assert eng._reserved_total == per_req
        assert eng.stats["kv_admission_blocked"] > 0
        eng.run_until_drained(max_steps=300)
        assert all(r.done for r in reqs)
        assert eng._reserved_total == 0
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages


# ------------------------------------------------- state snapshots
class TestStateSnapshots:
    def test_snapshot_roundtrip_bit_exact(self):
        """compress -> decompress of every recurrent-kind state is
        bit-identical, including the -1e30 mLSTM/sLSTM stabilizer init."""
        cfg = dataclasses.replace(configs.get_smoke_config("xlstm-125m"),
                                  kv_cache_dtype="apack-int8")
        kv = M.PagedKVCache(cfg, num_pages=0, page_size=4)
        kv.add_request(0)
        rng = np.random.default_rng(2)
        for layer in kv.state_layers:
            kind = kv.layer_kinds[layer]
            tmpl = kv._state_template(kind)
            kv.states[0][layer] = {
                f: (rng.normal(0, 3, v.shape).astype(np.float32)
                    if rng.uniform() < 0.8 else v.copy())
                for f, v in tmpl.items()}
        before = {l: {f: v.copy() for f, v in st.items()}
                  for l, st in kv.states[0].items()}
        snap = kv.snapshot_state(0)
        assert kv.traffic["state_snapshots"] == 1
        assert kv.traffic["state_raw_bytes"] > 0
        kv.add_request(1)
        kv.restore_state(1, snap)
        for layer, fields in before.items():
            for f, want in fields.items():
                got = kv.states[1][layer][f]
                assert got.dtype == np.float32
                assert np.array_equal(
                    got.view(np.uint32), want.view(np.uint32)), (layer, f)

    def test_snapshot_uses_weight_mode_tables(self):
        """Snapshot-time tables come from the paper's weight-mode
        heuristic (full profile, no activation slack) — stored-mode
        (near-uniform mantissa) planes excepted."""
        cfg = hetero_cfg()
        kv = M.PagedKVCache(cfg, num_pages=8, page_size=4)
        kv.add_request(0)
        rng = np.random.default_rng(3)
        for layer in kv.state_layers:
            tmpl = kv._state_template(kv.layer_kinds[layer])
            kv.states[0][layer] = {
                f: rng.normal(0, 1, v.shape).astype(np.float32)
                for f, v in tmpl.items()}
        snap = kv.snapshot_state(0)
        coded = [p for p in snap["planes"].planes if not p.stored.all()]
        assert coded, "every snapshot plane fell back to stored mode"
        assert all(p.table.mode == "weight" for p in coded)

    def test_engine_preempt_resume_is_bit_exact(self):
        """Preempting a heterogeneous request mid-decode (snapshot the
        recurrent states compressed, give up the slot) and resuming it
        produces exactly the uninterrupted token stream."""
        cfg = hetero_cfg()
        params = M.init_params(configs.get_hetero_smoke_config(), KEY)
        rng = np.random.default_rng(7)
        prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)

        def run(preempt_at=None):
            eng = ServeEngine(cfg, params, max_batch=2, max_len=40,
                              kv_page_size=4, kv_calib_pages=2)
            r = Request(rid=0, prompt=prompt.copy(), max_new_tokens=10)
            eng.submit(r)
            for step in range(100):
                if r.done:
                    break
                if step == preempt_at and eng.active[0] is not None:
                    eng.preempt(0)
                eng.step()
                eng._retire()
            return r.tokens, eng

        base_toks, _ = run()
        toks, eng = run(preempt_at=4)
        assert toks == base_toks
        assert eng.stats["preempted"] == 1 and eng.stats["resumed"] == 1
        st = eng.kv_stats()["kv_streams"]["state"]
        assert st["snapshots"] == 1 and st["raw_bytes"] > 0
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages


# ------------------------------------------ multi-table gather-decode
class TestMultiTableGatherDecode:
    def test_one_call_decodes_pages_with_different_tables(self):
        """The per-page table-id prefetch vector: pages encoded with two
        different activation tables decode bit-exactly in a single call."""
        rng = np.random.default_rng(11)
        e, s = 32, 4
        pages = np.stack([rng.normal(40, 10, (s, e)).astype(np.int64) & 0xFF,
                          rng.normal(200, 10, (s, e)).astype(np.int64) & 0xFF,
                          rng.normal(40, 10, (s, e)).astype(np.int64) & 0xFF])
        t_low = tables.table_for(pages[0].reshape(-1), is_activation=True)
        t_high = tables.table_for(pages[1].reshape(-1), is_activation=True)
        tabs = [t_low, t_high, t_low]
        planes = []
        for i in range(3):
            ta = _ref.TableArrays.from_table(tabs[i])
            planes.append(tuple(np.asarray(x) for x in
                                _ref.encode(jnp.asarray(pages[i]), ta, e, 8)))
        pooled = tuple(np.stack([p[i] for p in planes]) for i in range(5))
        sym, ofs, _, _, stored = pooled
        stack = [np.stack(x) for x in zip(*(t.as_arrays() for t in
                                            (t_low, t_high)))]
        idx = np.asarray([2, 1, 0], np.int32)
        tid = np.asarray([0, 1, 0], np.int32)
        for backend in ("ref", "pallas_interpret"):
            out = np.asarray(gather_decode(
                jnp.asarray(sym), jnp.asarray(ofs), jnp.asarray(stored),
                jnp.asarray(idx), jnp.asarray(stack[0]),
                jnp.asarray(stack[1]), jnp.asarray(stack[2]),
                n_steps=e, backend=backend, table_idx=jnp.asarray(tid)))
            for g, pid in enumerate(idx):
                assert np.array_equal(out[g], pages[pid]), (backend, g)


# ------------------------------------------------- -O invariant smoke
def test_pool_invariants_raise_under_python_O():
    """Bare asserts vanish under ``python -O``; the pool's invariant
    checks must not (they guard against silent data corruption)."""
    code = """
import numpy as np
from repro.models import modules as m
if __debug__:
    raise SystemExit("test harness error: -O not active")
pool = m.KVPagePool(2, 4, 2, 8, elems_per_stream=16)
pid = pool.alloc()
k = np.zeros((2, 8), np.int8); s = np.zeros(2, np.float32)
for _ in range(4):
    pool.write_token(pid, k, k, s, s)
try:
    pool.write_token(pid, k, k, s, s)
except RuntimeError as e:
    if "overfull" not in str(e):
        raise SystemExit("overfull raised without page state: %s" % e)
else:
    raise SystemExit("overfull write did not raise")
try:
    pool.seal(pid, np.zeros((2, 4, 2, 8), np.int8),
              np.zeros((2, 2), np.float32))
    pool.seal(pid, np.zeros((2, 4, 2, 8), np.int8),
              np.zeros((2, 2), np.float32))
except ValueError as e:
    pass
else:
    raise SystemExit("double seal did not raise")
pool.free(pid)
try:
    pool.free(pid)
except ValueError as e:
    if "double free" not in str(e):
        raise SystemExit("double free raised without page state: %s" % e)
else:
    raise SystemExit("double free did not raise")
pid2 = pool.alloc()
pool.write_token(pid2, k, k, s, s)
try:
    pool.evict(pid2)
except RuntimeError:
    pass
else:
    raise SystemExit("evict of HOT page did not raise")
print("POOL_INVARIANTS_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert "POOL_INVARIANTS_OK" in out.stdout


# --------------------------------------------------- no-traffic ratio
class TestNoTrafficRatio:
    def test_kv_ratio_none_before_any_read(self):
        """Table bytes can accrue (pages seal during appends) before a
        single read happens; the ratio must say "no data" — not 1.0."""
        cfg = hetero_cfg()
        kv = M.PagedKVCache(cfg, num_pages=32, page_size=4, calib_pages=1)
        kv.add_request(0)
        rng = np.random.default_rng(4)
        for _ in range(8):
            kv.append_token(0, *_random_token(rng, kv))
        assert kv.traffic["kv_table_bytes"] > 0        # calibrated already
        assert kv.traffic["kv_raw_bytes"] == 0         # ...but zero reads
        assert kv.kv_ratio() is None
        kv.materialize([0], 16)
        assert kv.kv_ratio() is not None

    def test_engine_with_no_attention_reports_none(self):
        """xLSTM stack: no attention layers, no pages, no KV reads — the
        engine serves fine and kv_stats reports the n/a ratio and the
        state stream explicitly."""
        base = configs.get_smoke_config("xlstm-125m")
        cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
        params = M.init_params(base, KEY)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=24,
                          kv_page_size=4)
        rng = np.random.default_rng(6)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=100)
        assert all(r.done for r in reqs)
        ks = eng.kv_stats()
        assert ks["kv_ratio"] is None
        assert ks["kv_pool_pages"] == 0
        assert ks["kv_streams"]["state"]["ratio"] is None


# ----------------------------------------- heterogeneous decode parity
class TestHeteroDecodeParity:
    def test_teacher_forced_logits_and_eviction(self):
        """The acceptance gate: a global + local + recurrent cycle decodes
        through the paged compressed cache within the raw-int8 envelope
        (0.35), rolling layers demonstrably free pages while the sequence
        grows, and the measured read ratio is < 1.0."""
        cfg16 = configs.get_hetero_smoke_config()
        cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8")
        cfga = hetero_cfg()
        params = M.init_params(cfg16, KEY)
        b, s = 2, 16
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, cfg16.vocab_size, (b, s)))
        kv = M.PagedKVCache(
            cfga, num_pages=b * M.PagedKVCache.pages_for_config(cfga, s, 4),
            page_size=4, calib_pages=2)
        rids = list(range(b))
        for rid in rids:
            kv.add_request(rid)
        cache16 = M.init_cache(cfg16, b, s)
        cache8 = M.init_cache(cfg8, b, s)
        l16s, l8s, las, free_trace = [], [], [], []
        for t in range(s):
            tok = tokens[:, t:t + 1]
            l16, cache16 = M.decode_step(cfg16, params, cache16, tok,
                                         jnp.asarray(t))
            l8, cache8 = M.decode_step(cfg8, params, cache8, tok,
                                       jnp.asarray(t))
            la, new_a = M.decode_step(cfga, params, kv.materialize(rids, s),
                                      tok, jnp.asarray(t))
            kv.append_step_tokens(new_a, rids, [t] * b)
            free_trace.append(kv.pool.free_count)
            l16s.append(l16)
            l8s.append(l8)
            las.append(la)
        d16 = np.asarray(jnp.concatenate(l16s, 1), np.float32)
        d8 = np.asarray(jnp.concatenate(l8s, 1), np.float32)
        da = np.asarray(jnp.concatenate(las, 1), np.float32)
        # compression ran and rolling eviction fired mid-decode
        assert kv.traffic["kv_pages_packed"] > 0
        assert kv.pool.evict_count > 0
        assert any(b2 > a2 for a2, b2 in zip(free_trace, free_trace[1:])), \
            free_trace
        assert kv.kv_ratio() < 1.0
        # all three stream kinds accounted
        st = kv.stream_stats()
        assert st["global"]["raw_bytes"] > 0
        assert st["local"]["raw_bytes"] > 0
        assert np.abs(da - d8).max() < 0.35, np.abs(da - d8).max()
        assert np.abs(da - d16).max() < 0.35, np.abs(da - d16).max()


# ------------------------------------------- every config constructs
class TestEveryConfigConstructs:
    @pytest.mark.parametrize("arch", configs.all_arch_ids())
    def test_paged_kv_constructs_for_every_config(self, arch):
        """The PR-2 constructor guard is gone: every config in
        ``src/repro/configs`` builds a PagedKVCache (pool sized per kind)."""
        cfg = dataclasses.replace(configs.get_smoke_config(arch),
                                  kv_cache_dtype="apack-int8")
        pages = M.PagedKVCache.pages_for_config(cfg, 32, 4)
        kv = M.PagedKVCache(cfg, num_pages=pages, page_size=4)
        assert kv.n_layers == cfg.num_layers
        assert len(kv.attn_layers) + len(kv.state_layers) == kv.n_layers

    @pytest.mark.parametrize("arch", ["recurrentgemma-9b", "kimi-k2-1t-a32b"])
    def test_engine_serves_hybrid_and_prefix_stacks(self, arch):
        """End-to-end decode through ServeEngine for a rolling+recurrent
        hybrid (window shrunk so eviction fires) and a global-prefix MoE."""
        base = configs.get_smoke_config(arch)
        if arch == "recurrentgemma-9b":
            base = dataclasses.replace(base, window_size=8)
        cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
        params = M.init_params(base, KEY)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=32,
                          kv_page_size=4, kv_calib_pages=2)
        rng = np.random.default_rng(8)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 9)
                        .astype(np.int32), max_new_tokens=6)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=300)
        assert all(r.done for r in reqs)
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages
        ks = eng.kv_stats()
        assert ks["kv_ratio"] is not None and ks["kv_ratio"] < 1.2
        if arch == "recurrentgemma-9b":
            assert ks["kv_pages_evicted"] > 0

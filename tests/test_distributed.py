"""Multi-device tests.  Run in subprocesses so the 8-device XLA flag never
leaks into the main test session (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_py(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    print(run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import model as M, sharding as sh
from repro.train import AdamWConfig, init_state
from repro.train.train_step import make_train_step

cfg = configs.get_smoke_config("qwen3-1.7b")
ocfg = AdamWConfig(lr=1e-3)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)))}
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt = init_state(ocfg, params)
step = make_train_step(cfg, ocfg)

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# 2x4 mesh with full sharding machinery
mesh = jax.make_mesh((2, 4), ("data", "model"))
p_sh = sh.param_shardings(mesh, params)
b_sh = sh.batch_shardings(mesh, batch)
o_sh = {"step": NamedSharding(mesh, P()),
        "m": p_sh, "v": p_sh}
with mesh, sh.mesh_context(mesh):
    params_s = jax.device_put(params, p_sh)
    opt_s = jax.device_put(opt, {"step": o_sh["step"], "m": p_sh, "v": p_sh})
    batch_s = jax.device_put(batch, b_sh)
    p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))(
        params_s, opt_s, batch_s)

assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, (m1["loss"], m2["loss"])
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, jax.device_get(p2))
worst = max(jax.tree.leaves(d))
assert worst < 5e-2, worst
print("SHARDED==SINGLE OK", float(m1["loss"]), worst)
"""))


@pytest.mark.slow
def test_compressed_allreduce_on_mesh():
    print(run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train import compress_grads as cg

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = {"w": jnp.asarray(rng.normal(0, 1e-3, (2048,)), jnp.float32)}
out, err = cg.compressed_psum_mean(g, mesh, ("data",))
# all replicas contributed the same g -> mean == dequantized g
ref = np.asarray(g["w"])
got = np.asarray(out["w"])
q, s, n = cg.quantize_blockwise(g["w"])
tol = float(np.max(np.asarray(s))) * 1.01
assert np.abs(got - ref).max() <= tol, np.abs(got - ref).max()
assert np.abs(np.asarray(err["w"]) + got - ref).max() < 1e-6
print("COMPRESSED ALLREDUCE OK")
"""))


@pytest.mark.slow
def test_dryrun_cell_small_mesh_decode():
    """The dry-run path works end-to-end on a small mesh (lower+compile a
    decode cell with cache shardings) — a fast proxy for the 512-chip run."""
    print(run_py(r"""
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import model as M, sharding as sh
import jax.numpy as jnp

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke_config("recurrentgemma-9b")
params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
cache = jax.eval_shape(lambda: M.init_cache(cfg, 8, 64))
p_sh = sh.param_shardings(mesh, params)
c_sh = sh.cache_shardings(mesh, cache)
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
pos = jax.ShapeDtypeStruct((), jnp.int32)

def fn(p, c, t, pos):
    return M.decode_step(cfg, p, c, t, pos)

with mesh, sh.mesh_context(mesh):
    compiled = jax.jit(fn, in_shardings=(
        p_sh, c_sh, NamedSharding(mesh, P(("data",), None)),
        NamedSharding(mesh, P()))).lower(params, cache, tok, pos).compile()
print("DECODE COMPILE OK", compiled.memory_analysis().temp_size_in_bytes)
"""))


@pytest.mark.slow
def test_elastic_reshard_across_mesh_shapes():
    """A checkpoint written from one mesh restores onto a different mesh
    (elastic rescale path)."""
    print(run_py(r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.ckpt import checkpoint as ckpt
from repro.models import sharding as sh
from repro import configs
from repro.models import model as M

cfg = configs.get_smoke_config("xlstm-125m")
params = M.init_params(cfg, jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
p1 = jax.device_put(params, sh.param_shardings(mesh1, params))
ckpt.save(d, 1, p1)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
restored, _, _ = ckpt.restore(d, shardings=sh.param_shardings(mesh2, params))
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC RESHARD OK")
"""))

"""Optimizer / train-step / gradient-compression / data-pipeline tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data import BinTokenDataset, DataConfig, SyntheticLM, write_bin
from repro.models import model as M
from repro.train import AdamWConfig, init_state, apply_updates, lr_schedule
from repro.train.optimizer import Q8, _q8_decode, _q8_encode
from repro.train.train_step import make_train_step
from repro.train import compress_grads as cg


class TestOptimizer:
    def _quadratic_converges(self, state_dtype):
        # min ||Wx - y||^2 — AdamW should reduce loss by >10x
        rng = np.random.default_rng(0)
        w0 = jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)
        y = jnp.asarray(rng.normal(0, 1, (8, 32)), jnp.float32)
        cfg = AdamWConfig(lr=3e-2, warmup_steps=5, total_steps=300,
                          weight_decay=0.0, state_dtype=state_dtype)
        params = {"w": w0}
        state = init_state(cfg, params)

        def loss(p):
            return jnp.mean((p["w"] @ x - y) ** 2)

        l0 = float(loss(params))
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = apply_updates(cfg, params, g, state)
        return l0, float(loss(params))

    def test_adamw_converges_fp32(self):
        l0, l1 = self._quadratic_converges("float32")
        assert l1 < l0 / 10

    def test_adamw_converges_int8_state(self):
        l0, l1 = self._quadratic_converges("int8")
        assert l1 < l0 / 5      # block-quantized moments still converge

    def test_q8_roundtrip_accuracy(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 0.1, (1024,)), jnp.float32)
        q = _q8_encode(x)
        out = _q8_decode(q, x.shape, x.size)
        # per-block absmax scaling bounds error by max|block|/127
        assert float(jnp.max(jnp.abs(out - x))) <= float(jnp.abs(x).max()) / 127 * 1.01

    def test_q8_shape_aligned(self):
        # q keeps the source shape so moments inherit param shardings
        x = jnp.ones((8, 224), jnp.float32)
        q = _q8_encode(x)
        assert q.q.shape == x.shape
        assert q.scale.shape == (8, 224 // 32)

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(0))) < 0.2
        assert abs(float(lr_schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert float(lr_schedule(cfg, jnp.asarray(100))) <= 0.11

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1e-6)
        params = {"w": jnp.ones((4,))}
        state = init_state(cfg, params)
        g = {"w": jnp.full((4,), 100.0)}
        new_p, _, m = apply_updates(cfg, params, g, state)
        assert float(m["grad_norm"]) == pytest.approx(200.0)
        assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 0.01


class TestTrainStep:
    def test_loss_decreases_on_learnable_data(self):
        cfg = configs.get_smoke_config("qwen3-1.7b")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
        data = SyntheticLM(DataConfig(batch_size=8, seq_len=64,
                                      vocab_size=cfg.vocab_size))
        step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(ocfg, params)
        losses = []
        for _ in range(30):
            b = data.next_batch()
            params, opt, metrics = step(params, opt,
                                        {"tokens": jnp.asarray(b["tokens"])})
            losses.append(float(metrics["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5

    def test_grad_accum_matches_full_batch(self):
        cfg = configs.get_smoke_config("xlstm-125m")
        ocfg = AdamWConfig(lr=1e-3)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (8, 32)))}
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(ocfg, params)
        p1, _, m1 = make_train_step(cfg, ocfg, grad_accum=1)(params, opt, batch)
        p2, _, m2 = make_train_step(cfg, ocfg, grad_accum=4)(params, opt, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                            - b.astype(jnp.float32)))), p1, p2)
        assert max(jax.tree.leaves(d)) < 2e-2   # bf16-level agreement


class TestGradCompression:
    def test_quantize_roundtrip(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 0.01, (3000,)), jnp.float32)
        q, s, n = cg.quantize_blockwise(g)
        out = cg.dequantize_blockwise(q, s, n, g.shape)
        assert float(jnp.max(jnp.abs(out - g))) <= float(s.max()) * 1.01

    def test_error_feedback_removes_bias(self):
        # repeated EF quantization of a constant gradient: the *running sum*
        # of dequantized outputs must track the true sum (bias-free)
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(0, 1e-3, (512,)), jnp.float32)
        e = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for i in range(50):
            q, s, n = cg.quantize_blockwise(g + e)
            deq = cg.dequantize_blockwise(q, s, n, g.shape)
            e = (g + e) - deq
            acc = acc + deq
        err = float(jnp.max(jnp.abs(acc / 50 - g)))
        assert err < float(jnp.abs(g).max()) * 0.05


class TestData:
    def test_synthetic_deterministic_resume(self):
        cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=100)
        a = SyntheticLM(cfg)
        b1 = [a.next_batch()["tokens"] for _ in range(3)]
        state = a.state_dict()
        b2 = a.next_batch()["tokens"]
        a2 = SyntheticLM(cfg)
        a2.load_state_dict(state)
        assert np.array_equal(a2.next_batch()["tokens"], b2)

    def test_synthetic_host_shards_differ(self):
        c0 = DataConfig(batch_size=2, seq_len=16, vocab_size=100, host_index=0)
        c1 = dataclasses.replace(c0, host_index=1)
        assert not np.array_equal(SyntheticLM(c0).next_batch()["tokens"],
                                  SyntheticLM(c1).next_batch()["tokens"])

    def test_bin_dataset_roundtrip(self, tmp_path):
        tokens = np.arange(10000) % 1000
        path = tmp_path / "toks.bin"
        write_bin(path, tokens)
        cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=1000)
        ds = BinTokenDataset(path, cfg)
        b = ds.next_batch()
        assert b["tokens"].shape == (2, 16)
        assert np.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        # resume determinism
        state = ds.state_dict()
        nxt = ds.next_batch()["tokens"]
        ds2 = BinTokenDataset(path, cfg)
        ds2.load_state_dict(state)
        assert np.array_equal(ds2.next_batch()["tokens"], nxt)

    def test_bin_dataset_hosts_disjoint(self, tmp_path):
        tokens = np.arange(20000) % 997
        path = tmp_path / "t.bin"
        write_bin(path, tokens)
        cfg0 = DataConfig(batch_size=1, seq_len=64, vocab_size=997,
                          host_index=0, host_count=2)
        cfg1 = dataclasses.replace(cfg0, host_index=1)
        b0 = BinTokenDataset(path, cfg0).next_batch()["tokens"]
        b1 = BinTokenDataset(path, cfg1).next_batch()["tokens"]
        assert not np.array_equal(b0, b1)

"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes + no NaNs; decode-vs-forward consistency; full-scale
param-count sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M

ARCHS = configs.all_arch_ids()
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {"frame_embeds": jnp.asarray(
                    rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, 8, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, KEY)
        batch = make_batch(cfg)
        logits, caches, aux = M.forward(cfg, params, batch)
        s_extra = 8 if cfg.frontend == "vision" else 0
        assert logits.shape == (2, 32 + s_extra, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        loss = M.loss_fn(cfg, logits, batch, aux)
        assert np.isfinite(float(loss))

    def test_train_step_grads_finite(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, KEY)
        batch = make_batch(cfg)

        def loss(p):
            logits, _, aux = M.forward(cfg, p, batch)
            return M.loss_fn(cfg, logits, batch, aux)

        val, grads = jax.value_and_grad(loss)(params)
        assert np.isfinite(float(val))
        finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
        assert all(jax.tree.leaves(finite)), arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_config(a).family != "encoder"])
def test_decode_matches_forward(arch):
    """Greedy per-position logits from the decode path must match the full
    forward pass — exercises every cache type (global/local kv, rolling
    window, RG-LRU, mLSTM, sLSTM)."""
    import dataclasses
    cfg = configs.get_smoke_config(arch)
    if cfg.num_experts:
        # capacity drops are a train-time semantic; for decode equivalence
        # use a no-drop capacity (cap == group size)
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts
                                       / cfg.num_experts_per_tok))
    params = M.init_params(cfg, KEY)
    b, s = 2, 16
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.frontend == "vision":
        # decode equivalence on pure-text input (no image prefix)
        pass
    full_logits, _, _ = M.forward(cfg, params, batch, remat=False)
    cache = M.init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        lg, cache = M.decode_step(cfg, params, cache,
                                  batch["tokens"][:, t:t + 1], jnp.asarray(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    # xlstm accumulates bf16 ulp-level divergence between the scan-fused and
    # step paths (decode matches an unrolled forward bit-exactly; the scan
    # fusion context changes bf16 dot rounding) — slightly looser tolerance.
    tol = 0.08 if arch == "xlstm-125m" else 2e-2
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """The full config's analytic size must land near the arch's nameplate."""
    nameplate = {
        "qwen3-1.7b": 1.7e9, "minitron-4b": 4.2e9, "minitron-8b": 7.7e9,
        "command-r-plus-104b": 104e9, "hubert-xlarge": 0.96e9,
        "paligemma-3b": 2.5e9,   # text backbone only (vision stub excluded)
        "dbrx-132b": 132e9, "kimi-k2-1t-a32b": 1.03e12,
        "xlstm-125m": 0.125e9, "recurrentgemma-9b": 8.5e9,
    }[arch]
    cfg = configs.get_config(arch)
    est = cfg.param_count()
    assert abs(est - nameplate) / nameplate < 0.30, (arch, est, nameplate)


def test_moe_active_params():
    kimi = configs.get_config("kimi-k2-1t-a32b")
    assert abs(kimi.active_param_count() - 33e9) / 33e9 < 0.15
    dbrx = configs.get_config("dbrx-132b")
    assert abs(dbrx.active_param_count() - 36e9) / 36e9 < 0.15


def test_local_attention_window_masks_past():
    """Tokens beyond the window must not influence local-attention output."""
    cfg = configs.get_smoke_config("recurrentgemma-9b")
    params = M.init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    s = 64   # window is 32 in the smoke config
    t1 = rng.integers(0, cfg.vocab_size, (1, s))
    t2 = t1.copy()
    t2[0, :8] = rng.integers(0, cfg.vocab_size, 8)    # differ far in the past
    l1, _, _ = M.forward(cfg, params, {"tokens": jnp.asarray(t1)}, remat=False)
    l2, _, _ = M.forward(cfg, params, {"tokens": jnp.asarray(t2)}, remat=False)
    # recurrent blocks do carry long-range state, so compare only local-attn
    # reach: with window 32, the last position's attention context starts at
    # 33; the recurrent path decays but is not exactly zero -> allow loose
    # tolerance on the final position while asserting early positions differ.
    assert not np.allclose(np.asarray(l1[0, 8]), np.asarray(l2[0, 8]))


def test_encoder_is_bidirectional():
    cfg = configs.get_smoke_config("hubert-xlarge")
    params = M.init_params(cfg, KEY)
    rng = np.random.default_rng(3)
    fe = rng.normal(0, 1, (1, 16, cfg.d_model)).astype(np.float32)
    fe2 = fe.copy()
    fe2[0, -1] += 10.0                               # perturb the LAST frame
    l1, _, _ = M.forward(cfg, params, {"frame_embeds": jnp.asarray(fe)})
    l2, _, _ = M.forward(cfg, params, {"frame_embeds": jnp.asarray(fe2)})
    # first-frame logits must change => attention attends forward
    assert not np.allclose(np.asarray(l1[0, 0]), np.asarray(l2[0, 0]))
